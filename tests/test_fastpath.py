"""Epoch-batched fast path (ISSUE 6): bit-identical RunReports vs the event
loop, epoch-slicing invariants, per-queue writeback thresholds, and the
alloc-failure attribution bugfix.

The engine's contract is absolute: for every config, ``engine="epoch"``
produces the same RunReport as ``engine="event"`` — either through the
closed-form fast path (validated pure, committed atomically) or by falling
back to the event loop itself.  These tests pin both halves: fast-path
configs must *stay on* the fast path (and match bit-for-bit), unsupported
configs must fall back (and trivially match).
"""
import numpy as np
import pytest

from repro.core import (BypassL2FwdServer, EpochRunInfo, LoadGen, PacketPool,
                        Port, SimClock, TrafficPattern, run_epoch_sim)
from repro.core.fastpath import default_epoch_ns, iter_epoch_slices
from repro.exp import (DcaConfig, ExperimentConfig, NodeConfig, PoolConfig,
                       PortConfig, StackConfig, TopologyConfig, TrafficConfig,
                       Testbed, run_experiment)
from repro.exp.testbed import effective_writeback_threshold
from repro.exp.topology import Cluster


def build(n_queues=4, ring=1024, wb=32, burst=64, n_lcores=4, gbps=40.0,
          lat=1000, pool_slots=8192, nports=1):
    pools = [PacketPool(pool_slots, 2048) for _ in range(nports)]
    ports = [Port.make(pools[i], ring_size=ring, writeback_threshold=wb,
                       n_queues=n_queues, link_gbps=gbps, link_latency_ns=lat)
             for i in range(nports)]
    server = BypassL2FwdServer(ports, burst_size=burst, n_lcores=n_lcores)
    clock = SimClock()
    server.attach_clock(clock)
    return server, ports, clock


def report_key(rep):
    """Every observable in a RunReport, comparable bit-for-bit."""
    lat = None if rep.latency is None else rep.latency.as_dict()
    return (rep.offered_gbps, rep.achieved_gbps, rep.achieved_mpps, rep.sent,
            rep.received, rep.dropped, lat,
            tuple(tuple(sorted(h.items())) for h in rep.histogram),
            tuple(sorted(rep.extras.items())))


def queue_stats_key(server):
    return {k: (v.rx_packets, v.tx_packets, v.rx_bytes, v.burst_count,
                v.burst_packets, tuple(v.burst_buckets))
            for k, v in server.per_queue_stats().items()}


def run_pair(pattern, dur, use_jax=False, **kw):
    """One config, both engines, fresh state each: returns both observations
    plus the epoch engine's out-of-band info."""
    server, ports, clock = build(**kw)
    lg = LoadGen(ports)
    rep_e = lg.run_sim(server, pattern, duration_s=dur, clock=clock)
    ev = (report_key(rep_e), queue_stats_key(server), clock.now_ns)

    server2, ports2, clock2 = build(**kw)
    lg2 = LoadGen(ports2)
    info = EpochRunInfo()
    rep_f = run_epoch_sim(lg2, server2, pattern, duration_s=dur, clock=clock2,
                          use_jax=use_jax, info=info)
    ep = (report_key(rep_f), queue_stats_key(server2), clock2.now_ns)
    return ev, ep, info


# -- engine equivalence: fast-path configs ------------------------------------

FASTPATH_CASES = [
    ("uniform-4q", TrafficPattern(rate_gbps=40.0, packet_size=1518),
     0.002, {}),
    ("poisson-4q", TrafficPattern(rate_gbps=40.0, packet_size=1518,
                                  kind="poisson", seed=3), 0.002, {}),
    ("bursty-4q", TrafficPattern(rate_gbps=40.0, packet_size=1518,
                                 kind="bursty", burst_len=32), 0.002, {}),
    ("uniform-1q", TrafficPattern(rate_gbps=2.0, packet_size=1518),
     0.002, dict(n_queues=1, n_lcores=1)),
    ("two-ports", TrafficPattern(rate_gbps=40.0, packet_size=1518),
     0.002, dict(nports=2, n_lcores=8)),
    ("ideal-wire", TrafficPattern(rate_gbps=40.0, packet_size=1518),
     0.001, dict(gbps=0.0, lat=0)),
    ("one-lcore-4q", TrafficPattern(rate_gbps=20.0, packet_size=1518),
     0.002, dict(n_lcores=1)),
]


@pytest.mark.parametrize("name,pattern,dur,kw", FASTPATH_CASES,
                         ids=[c[0] for c in FASTPATH_CASES])
def test_epoch_engine_bit_identical_on_fastpath(name, pattern, dur, kw):
    ev, ep, info = run_pair(pattern, dur, **kw)
    assert info.fastpath, info.fallback_reason  # must NOT have fallen back
    assert info.n_packets > 0
    assert ev == ep


# -- engine equivalence: fallback configs -------------------------------------

FALLBACK_CASES = [
    # whole-ring writeback (threshold None) couples publishes to ring-full
    ("wb-none", TrafficPattern(rate_gbps=5.0, packet_size=1518),
     0.001, dict(wb=None, ring=64)),
    # 64B @ 100G overloads 4 lcores: the ring genuinely fills (event loop
    # drops too) — validation must force the event loop, not approximate
    ("overload-64B-100G", TrafficPattern(rate_gbps=100.0, packet_size=64),
     0.0005, {}),
    # one lcore at ~551 ns/pkt cannot keep up with 256B @ 10G (~205 ns/pkt)
    ("overload-1q", TrafficPattern(rate_gbps=10.0, packet_size=256),
     0.001, dict(n_queues=1, n_lcores=1)),
]


@pytest.mark.parametrize("name,pattern,dur,kw", FALLBACK_CASES,
                         ids=[c[0] for c in FALLBACK_CASES])
def test_epoch_engine_falls_back_and_matches(name, pattern, dur, kw):
    ev, ep, info = run_pair(pattern, dur, **kw)
    assert not info.fastpath and info.fallback_reason
    assert ev == ep


def test_epoch_jit_matches_when_available():
    from repro.kernels.epoch_fastpath import get_epoch_pass_jax
    if get_epoch_pass_jax() is None:
        pytest.skip("JAX (with exact int64 pass) unavailable")
    pattern = TrafficPattern(rate_gbps=40.0, packet_size=1518, kind="poisson",
                             seed=7)
    ev, ep, info = run_pair(pattern, 0.002, use_jax=True)
    assert info.fastpath and info.used_jax
    assert ev == ep


# -- engine equivalence through run_experiment (paper-config shapes) ----------

def _fig_configs():
    fig3a = ExperimentConfig(
        name="fig3a-like",
        pool=PoolConfig(n_slots=16384, slot_size=1518),
        ports=(PortConfig(n_queues=4, ring_size=1024,
                          writeback_threshold=32),),
        stack=StackConfig(kind="bypass", burst_size=64),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=20.0,
                              duration_s=0.002))
    fig3b = fig3a.with_ports(writeback_threshold=128)
    # fig4-style: sim-time DCA accumulate + writeback-timeout timers — the
    # epoch engine must detect the armed timers and run the event loop
    fig4 = ExperimentConfig(
        name="fig4-like",
        pool=PoolConfig(n_slots=16384, slot_size=1518),
        ports=(PortConfig(n_queues=2, ring_size=1024),),
        stack=StackConfig(kind="bypass", burst_size=32),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=10.0,
                              duration_s=0.002, kind="bursty", burst_len=64),
        dca=DcaConfig(burst_size=64, writeback_threshold=16,
                      writeback_timeout_ns=50_000))
    # timeout-timer dominant: threshold too high to cross within a burst
    timer = fig4.with_dca(writeback_threshold=512, burst_size=32)
    return [("fig3a", fig3a), ("fig3b", fig3b), ("fig4-dca", fig4),
            ("timer", timer)]


@pytest.mark.parametrize("name,cfg", _fig_configs(),
                         ids=[n for n, _ in _fig_configs()])
def test_run_experiment_engine_parity(name, cfg):
    rep_e = run_experiment(cfg.with_traffic(engine="event"))
    rep_f = run_experiment(cfg.with_traffic(engine="epoch"))
    assert report_key(rep_e) == report_key(rep_f)


def test_dca_config_forces_fallback():
    """Armed writeback timers / DCA accumulate are outside the fast-path
    regime; the engine must refuse them statically (not mis-simulate)."""
    _, cfg = _fig_configs()[2]
    tb = Testbed.build(cfg)
    t = cfg.traffic
    pattern = TrafficPattern(rate_gbps=t.rate_gbps, packet_size=t.packet_size,
                             kind=t.kind, burst_len=t.burst_len, seed=t.seed)
    info = EpochRunInfo()
    run_epoch_sim(tb.loadgen, tb.server, pattern, duration_s=t.duration_s,
                  clock=tb.clock, sched=tb.sched, info=info)
    assert not info.fastpath and info.fallback_reason


# -- epoch slicing of the emission schedule -----------------------------------

def _schedules():
    out = []
    for kind, seed in [("uniform", 0), ("poisson", 1), ("bursty", 2)]:
        p = TrafficPattern(rate_gbps=25.0, packet_size=512, kind=kind,
                           seed=seed, burst_len=16)
        times, _ = p.emission_schedule(2_000_000,
                                       np.random.default_rng(seed))
        out.append((kind, np.sort(times)))
    return out


@pytest.mark.parametrize("kind,times", _schedules(),
                         ids=[k for k, _ in _schedules()])
@pytest.mark.parametrize("epoch_ns", [1, 1000, 77_777, 10_000_000])
def test_epoch_slices_partition_in_order(kind, times, epoch_ns):
    """No packet lost or reordered at epoch boundaries: the slices are a
    contiguous, in-order, exhaustive partition of the schedule, and every
    slice stays inside one epoch window."""
    slices = list(iter_epoch_slices(times, epoch_ns))
    assert slices, "nonempty schedule must yield slices"
    assert slices[0][0] == 0 and slices[-1][1] == len(times)
    t0 = int(times[0])
    for (lo, hi), (lo2, _) in zip(slices, slices[1:] + [(len(times), None)]):
        assert lo < hi, "slices are nonempty"
        assert hi == lo2, "slices are contiguous (nothing lost or duplicated)"
        # all times in one slice share the window keyed by its first element
        k = (int(times[lo]) - t0) // epoch_ns
        assert (int(times[hi - 1]) - t0) // epoch_ns == k
    # reassembly is the identity — order preserved
    joined = np.concatenate([times[lo:hi] for lo, hi in slices])
    assert np.array_equal(joined, times)


def test_epoch_slices_empty_and_degenerate():
    assert list(iter_epoch_slices(np.empty(0, dtype=np.int64), 100)) == []
    times = np.array([5, 5, 5], dtype=np.int64)
    assert list(iter_epoch_slices(times, 10)) == [(0, 3)]
    # epoch_ns <= 0 degrades to one slice covering everything
    assert list(iter_epoch_slices(times, 0)) == [(0, 3)]


def test_default_epoch_ns_bounds():
    pool = PacketPool(64, 2048)
    port = Port.make(pool, link_gbps=100.0, link_latency_ns=1_000)
    times = np.arange(0, 10_000, 100, dtype=np.int64)
    e = default_epoch_ns([port], times)
    assert e >= 1_000  # never below the min link latency (SimBricks bound)
    # huge schedules get chunked near the 64k-packet target
    big = np.arange(1 << 20, dtype=np.int64) * 50
    e_big = default_epoch_ns([port], big)
    n_slices = len(list(iter_epoch_slices(big, e_big)))
    assert 2 <= n_slices <= 32


# -- per-queue writeback thresholds (satellite) -------------------------------

def test_per_queue_thresholds_validation():
    with pytest.raises(ValueError, match="2 entries"):
        ExperimentConfig(ports=(PortConfig(n_queues=4),),
                         dca=DcaConfig(per_queue_writeback_thresholds=(8, 8)))
    with pytest.raises(ValueError, match=">= 1 or None"):
        DcaConfig(per_queue_writeback_thresholds=(0, 1))
    with pytest.raises(ValueError, match="exceeds"):
        ExperimentConfig(
            ports=(PortConfig(n_queues=2, ring_size=64),),
            dca=DcaConfig(per_queue_writeback_thresholds=(128, 1)))
    with pytest.raises(ValueError, match="nonempty"):
        DcaConfig(per_queue_writeback_thresholds=())


def test_per_queue_thresholds_fold_through_testbed():
    cfg = ExperimentConfig(
        ports=(PortConfig(n_queues=4),),
        dca=DcaConfig(per_queue_writeback_thresholds=(8, None, 64, 1)))
    tb = Testbed.build(cfg)
    thrs = [rq.writeback_threshold for rq in tb.devs[0].rx_queues]
    # None entries fall through to the DcaConfig-global threshold (32)
    assert thrs == [8, 32, 64, 1]
    # round-trips through plain dicts (JSON) exactly
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


def test_per_queue_thresholds_fold_through_topology():
    cfg = TopologyConfig(
        nodes=(NodeConfig(
            name="srv", port=PortConfig(n_queues=2),
            dca=DcaConfig(per_queue_writeback_thresholds=(4, 16))),),
        traffic=TrafficConfig(mode="open_loop", duration_s=0.0005))
    cluster = Cluster.build(cfg)
    thrs = [rq.writeback_threshold
            for rq in cluster.nodes[0].dev.rx_queues]
    assert thrs == [4, 16]


def test_effective_writeback_threshold_helper():
    dca = DcaConfig(writeback_threshold=32,
                    per_queue_writeback_thresholds=(8, None))
    assert effective_writeback_threshold(dca, 99, 0) == 8
    assert effective_writeback_threshold(dca, 99, 1) == 32   # falls through
    assert effective_writeback_threshold(None, 99, 1) == 99  # legacy
    with pytest.raises(ValueError, match="out of range"):
        dca.threshold_for(2)


# -- alloc-failure attribution (satellite bugfix) -----------------------------

def test_alloc_failures_attributed_in_report():
    """A frame that fails pool.alloc() counts toward ``sent`` (offered load)
    but used to vanish without attribution; it must now show up as
    ``extras["loadgen_alloc_failures"]``.  4 slots cannot carry a 2000-packet
    open-loop run, so starvation is guaranteed."""
    server, ports, clock = build(pool_slots=4, n_queues=1, n_lcores=1)
    lg = LoadGen(ports)
    pattern = TrafficPattern(rate_gbps=40.0, packet_size=1518)
    rep = lg.run_sim(server, pattern, duration_s=0.0005, clock=clock)
    failures = rep.extras["loadgen_alloc_failures"]
    assert failures > 0
    # every failed emission is part of `sent` but never reached a wire:
    # the unattributed gap this bugfix closes
    assert failures <= rep.sent - rep.received
    assert rep.dropped >= failures


def test_alloc_failures_zero_on_healthy_run():
    server, ports, clock = build()
    lg = LoadGen(ports)
    pattern = TrafficPattern(rate_gbps=10.0, packet_size=1518)
    rep = lg.run_sim(server, pattern, duration_s=0.001, clock=clock)
    assert rep.extras["loadgen_alloc_failures"] == 0.0
    assert rep.dropped == 0


def test_alloc_failure_starved_run_engine_parity():
    """Buffer starvation is outside the fast-path regime (the plan's pool
    validation rejects it) — but the fallback keeps reports identical."""
    pattern = TrafficPattern(rate_gbps=40.0, packet_size=1518)
    ev, ep, info = run_pair(pattern, 0.0005, pool_slots=4, n_queues=1,
                            n_lcores=1)
    assert not info.fastpath
    assert ev == ep
