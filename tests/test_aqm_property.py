"""Property tests for the AQM/DCTCP surfaces (hypothesis).

Randomized sweeps pin the three invariants the hand-picked cases in
``test_aqm_pipeline.py`` cannot cover exhaustively:

* the RED curve is monotone non-decreasing in queue depth, 0 below
  ``min_thresh`` and certain at ``max_thresh``, for any valid band;
* the DCTCP controller's rate never leaves ``[min_gbps, max_gbps]`` under
  arbitrary interleavings of sends, clean acks, marked acks, and time gaps;
* the CE bit survives every header transform the echo path applies —
  scalar and vectorized — and a frame never gains a mark it wasn't given.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import DctcpRateController, PacketPool, red_probability
from repro.core.packet import (MIN_FRAME, l2fwd_echo, l2fwd_echo_vec,
                               read_ce, read_ce_vec, set_ce, set_ce_vec,
                               swap_flow_ips, swap_flow_ips_vec, swap_macs,
                               swap_macs_vec, write_flow, write_packets_vec)


@settings(max_examples=100, deadline=None)
@given(min_thresh=st.integers(1, 64),
       band=st.integers(0, 64),
       max_p=st.floats(0.01, 1.0),
       d1=st.integers(0, 160), d2=st.integers(0, 160))
def test_red_probability_monotone_in_depth(min_thresh, band, max_p, d1, d2):
    max_thresh = min_thresh + band
    lo, hi = sorted((d1, d2))
    p_lo = red_probability(lo, min_thresh, max_thresh, max_p)
    p_hi = red_probability(hi, min_thresh, max_thresh, max_p)
    assert 0.0 <= p_lo <= p_hi <= 1.0
    assert red_probability(max_thresh, min_thresh, max_thresh, max_p) == 1.0
    assert red_probability(min_thresh - 1, min_thresh, max_thresh,
                           max_p) == 0.0


@settings(max_examples=100, deadline=None)
@given(events=st.lists(
    st.tuples(st.sampled_from(["send", "ack", "mark", "gap"]),
              st.integers(1, 50_000)),
    max_size=60),
    gain=st.floats(0.01, 1.0),
    increase=st.floats(0.01, 2.0),
    max_gbps=st.floats(1.0, 100.0))
def test_dctcp_rate_never_leaves_its_clamp(events, gain, increase, max_gbps):
    """Arbitrary mark/loss histories: the rate stays inside the clamp, the
    running min/max brackets hold, and the emission gap stays positive at
    every step."""
    cc = DctcpRateController(rate_gbps=max_gbps / 2, window_ns=10_000,
                             gain=gain, min_gbps=0.05, max_gbps=max_gbps,
                             increase_gbps=increase)
    t = 0
    sent_ts = []
    for op, dt in events:
        t += dt
        if op == "send":
            cc.on_send(t)
            sent_ts.append(t)
        elif op in ("ack", "mark") and sent_ts:
            cc.on_ack(t, ce=(op == "mark"), sent_ns=sent_ts.pop(0))
        else:
            cc.on_send(t)       # a gap still rolls windows via the clock
            sent_ts.append(t)
        assert 0.05 <= cc.rate_gbps <= max_gbps
        assert cc.rate_min <= cc.rate_gbps <= cc.rate_max
        assert cc.outstanding >= 0
        assert cc.gap_ns(1518) > 0


@settings(max_examples=50, deadline=None)
@given(size=st.integers(MIN_FRAME, 1518),
       src=st.integers(0, 0xFFFFFFFF), dst=st.integers(0, 0xFFFFFFFF),
       ce=st.booleans())
def test_ce_bit_survives_header_transforms(size, src, dst, ce):
    buf = np.zeros(size, dtype=np.uint8)
    write_flow(buf, src, dst, 1024, 443)
    if ce:
        set_ce(buf)
    for fn in (swap_macs, swap_flow_ips, l2fwd_echo):
        fn(buf)
        assert read_ce(buf) is ce

    pool = PacketPool(8, 2048)
    slots = np.array(pool.alloc_burst(4), dtype=np.int64)
    sizes = np.full(4, size, dtype=np.int64)
    write_packets_vec(pool, slots, sizes, seq_start=0, ts_offset=32,
                      now_ns=0, flow_ids=np.arange(4, dtype=np.int64))
    if ce:
        set_ce_vec(pool, slots)
    for fn in (swap_macs_vec, swap_flow_ips_vec, l2fwd_echo_vec):
        fn(pool, slots, sizes)
        marks = read_ce_vec(pool, slots)
        assert bool(marks.all()) is ce and bool(marks.any()) is ce
