"""Multi-queue RSS NIC model: hash correctness/balance, packet conservation,
per-queue stats aggregation, and lcore-schedule determinism."""
import numpy as np
import pytest

from repro.core import (BurstPlan, BypassL2FwdServer, KernelStackServer,
                        LoadGen, PacketPool, Port, RssIndirection,
                        TrafficPattern, flow_tuple_for_id, rss_skew,
                        toeplitz_hash, toeplitz_hash_vec, write_flow)
from repro.core.cost import HostCostModel


def _flow_bytes(src_ip, dst_ip, sport, dport):
    raw = (src_ip.to_bytes(4, "big") + dst_ip.to_bytes(4, "big")
           + sport.to_bytes(2, "big") + dport.to_bytes(2, "big"))
    return np.frombuffer(raw, dtype=np.uint8)


def _ip(a, b, c, d):
    return (a << 24) | (b << 16) | (c << 8) | d


def test_toeplitz_matches_microsoft_vectors():
    """The hash is the real RSS algorithm: verify against the published
    Microsoft verification-suite vectors (IPv4 with ports)."""
    vectors = [
        ((_ip(66, 9, 149, 187), _ip(161, 142, 100, 80), 2794, 1766), 0x51CCC178),
        ((_ip(199, 92, 111, 2), _ip(65, 69, 140, 83), 14230, 4739), 0xC626B0EA),
        ((_ip(24, 19, 198, 95), _ip(12, 22, 207, 184), 12898, 38024), 0x5C2B394A),
        ((_ip(38, 27, 205, 30), _ip(209, 142, 163, 6), 48228, 2217), 0xAFC7327F),
        ((_ip(153, 39, 163, 191), _ip(202, 188, 127, 2), 44251, 1303), 0x10E828A2),
    ]
    for args, want in vectors:
        assert toeplitz_hash(_flow_bytes(*args)) == want


def test_toeplitz_vectorized_matches_scalar():
    rng = np.random.default_rng(7)
    flows = rng.integers(0, 256, size=(64, 12), dtype=np.uint8)
    vec = toeplitz_hash_vec(flows)
    for i in range(len(flows)):
        assert int(vec[i]) == toeplitz_hash(flows[i])


def test_hash_distribution_balances_queues():
    """Distinct flows spread near-uniformly over queues (RSS's whole point)."""
    n_flows, n_queues = 4096, 4
    flows = np.stack([
        np.frombuffer(
            b"".join(int(x).to_bytes(n, "big") for x, n in
                     zip(flow_tuple_for_id(f), (4, 4, 2, 2))),
            dtype=np.uint8)
        for f in range(n_flows)
    ])
    rss = RssIndirection(n_queues)
    queues = rss.steer(flows)
    counts = np.bincount(queues, minlength=n_queues)
    assert counts.min() > 0
    skew = rss_skew(list(counts))
    assert skew["max_over_mean"] < 1.3, f"queue counts too skewed: {counts}"


def test_flow_affinity():
    """All packets of one flow land on one queue — no intra-flow reordering."""
    rss = RssIndirection(8)
    flow = _flow_bytes(_ip(10, 0, 0, 1), _ip(192, 168, 0, 1), 5555, 443)
    qs = rss.steer(np.repeat(flow.reshape(1, -1), 32, axis=0))
    assert len(set(int(q) for q in qs)) == 1


def test_scalar_steer_one_matches_vectorized():
    """The allocation-free single-packet path (table-lookup Toeplitz) must
    agree with the vectorized burst path bit for bit — default key, custom
    key, and after a rebalance."""
    rng = np.random.default_rng(11)
    flows = rng.integers(0, 256, size=(512, 12), dtype=np.uint8)
    for key in (None, bytes(rng.integers(0, 256, size=40, dtype=np.uint8))):
        rss = RssIndirection(4, key=key)
        vec = rss.steer(flows)
        for i in range(len(flows)):
            assert rss.steer_one(flows[i]) == int(vec[i])
            assert rss.hash_one(flows[i]) == int(
                toeplitz_hash_vec(flows[i].reshape(1, -1), key=key)[0])
        # (1, 12)-shaped input (the legacy calling convention) still works
        assert rss.steer_one(flows[0].reshape(1, -1)) == int(vec[0])
    rss = RssIndirection(4)
    rss.rebalance([3] * 128)
    assert all(rss.steer_one(flows[i]) == 3 for i in range(16))
    with pytest.raises(ValueError):
        rss.hash_one(flows[0][:8])  # not a 12-byte tuple


def test_indirection_rebalance():
    rss = RssIndirection(4)
    rss.rebalance([0] * 128)  # pin everything to queue 0
    flows = np.random.default_rng(3).integers(0, 256, size=(100, 12), dtype=np.uint8)
    assert (rss.steer(flows) == 0).all()
    with pytest.raises(ValueError):
        rss.rebalance([7] * 128)  # names a queue that doesn't exist


def _mk_bypass(n_queues=4, n_lcores=4, pool_slots=8192, ring=512, **kw):
    pool = PacketPool(pool_slots, 1518)
    ports = [Port.make(pool, ring_size=ring, n_queues=n_queues)]
    return BypassL2FwdServer(ports, n_lcores=n_lcores, **kw), ports


def test_multiqueue_closed_loop_conserves_packets():
    """Acceptance: 1 port / 4 queues / 4 lcores, closed loop — zero
    unattributed loss and per-queue stats summing to the aggregate."""
    server, ports = _mk_bypass()
    lg = LoadGen(ports, verify_integrity=True)
    rep = lg.run_closed_loop(server, n_packets=2000, packet_size=256,
                             rng=np.random.default_rng(0))
    assert rep.received == 2000
    assert rep.dropped == 0
    assert rep.extras["integrity_errors"] == 0
    per_queue = server.per_queue_stats()
    assert set(per_queue) == {(0, q) for q in range(4)}
    agg = server.stats
    assert sum(s.rx_packets for s in per_queue.values()) == agg.rx_packets == 2000
    assert sum(s.tx_packets for s in per_queue.values()) == agg.tx_packets
    assert sum(s.rx_bytes for s in per_queue.values()) == agg.rx_bytes
    # every queue saw traffic (256 default flows over 4 queues)
    assert all(s.rx_packets > 0 for s in per_queue.values())
    # NIC-side per-queue accounting reached the report and sums to sent
    delivered = sum(rep.extras[f"p0q{q}_rx_delivered"] for q in range(4))
    dropped = sum(rep.extras[f"p0q{q}_rx_dropped"] for q in range(4))
    assert delivered + dropped == rep.sent


def test_multiqueue_open_loop_accounts_every_packet():
    """sent == received + attributable drops under overload, multi-queue."""
    pool = PacketPool(256, 1518)
    ports = [Port.make(pool, ring_size=16, writeback_threshold=8, n_queues=4)]

    class DeadServer:
        def poll_once(self):
            return 0

    lg = LoadGen(ports)
    rep = lg.run(DeadServer(), TrafficPattern(rate_gbps=5.0, packet_size=1518),
                 duration_s=0.05, drain_timeout_s=0.05)
    assert rep.sent > 0
    assert rep.dropped > 0
    assert rep.received + rep.dropped == rep.sent


def test_lcore_round_robin_schedule_is_deterministic():
    """Two identical single-core runs produce identical per-queue stats."""
    def run_once():
        server, ports = _mk_bypass(burst_size=16)
        lg = LoadGen(ports)
        lg.run_closed_loop(server, n_packets=1500, packet_size=200, window=64)
        return {
            k: (v.rx_packets, v.tx_packets, v.rx_bytes, v.burst_count,
                v.burst_packets)
            for k, v in server.per_queue_stats().items()
        }
    assert run_once() == run_once()


def test_lcore_assignment_covers_all_queues():
    server, _ = _mk_bypass(n_queues=4, n_lcores=3)
    assigned = [pair for lc in server.lcores for pair in lc.assignments]
    assert sorted(assigned) == [(0, 0), (0, 1), (0, 2), (0, 3)]
    # round-robin: 3 lcores over 4 queues -> loads 2/1/1
    assert sorted(len(lc.assignments) for lc in server.lcores) == [1, 1, 2]


def test_per_lcore_burst_plan():
    plan = BurstPlan(per_lcore=(8, 64))
    server, ports = _mk_bypass(n_queues=2, n_lcores=2, plan=plan)
    assert [lc.burst_size for lc in server.lcores] == [8, 64]
    lg = LoadGen(ports)
    rep = lg.run_closed_loop(server, n_packets=500, packet_size=128)
    assert rep.received == 500
    with pytest.raises(ValueError):
        BurstPlan(per_lcore=(0,))


def test_kernel_stack_multiqueue_conservation():
    pool = PacketPool(8192, 1518)
    ports = [Port.make(pool, ring_size=512, n_queues=2)]
    server = KernelStackServer(ports, cost_model=HostCostModel(
        interrupt_cycles=0, syscall_cycles=0, per_packet_kernel_cycles=0))
    lg = LoadGen(ports, verify_integrity=True)
    rep = lg.run_closed_loop(server, n_packets=600, packet_size=300,
                             rng=np.random.default_rng(2))
    assert rep.received == 600
    assert rep.extras["integrity_errors"] == 0
    per_queue = server.per_queue_stats()
    assert sum(s.rx_packets for s in per_queue.values()) == 600
    assert all(s.interrupts > 0 for s in per_queue.values())
    assert server.stats.copies >= 3 * 600  # still 3 copies per packet


def test_burst_histogram_is_bounded():
    """Satellite: stats memory stays O(1) however long the run is."""
    server, ports = _mk_bypass(n_queues=1, n_lcores=1)
    lg = LoadGen(ports)
    lg.run_closed_loop(server, n_packets=3000, packet_size=128, window=64)
    agg = server.stats
    assert agg.burst_count > 0
    assert agg.burst_buckets.shape == server.stats_cls().burst_buckets.shape
    hist = agg.burst_histogram
    assert sum(b["count"] for b in hist) == agg.burst_count
    assert agg.avg_burst == pytest.approx(agg.burst_packets / agg.burst_count)


def test_single_queue_port_keeps_seed_semantics():
    """n_queues=1 ports bypass hashing and expose the legacy .rx/.tx views."""
    pool = PacketPool(1024, 1518)
    port = Port.make(pool, ring_size=128)
    assert port.n_queues == 1
    assert port.rx is port.rx_queues[0]
    assert port.tx is port.tx_queues[0]
    server = BypassL2FwdServer([port], burst_size=16)
    lg = LoadGen([port])
    rep = lg.run_closed_loop(server, n_packets=200, packet_size=128)
    assert rep.received == 200 and rep.dropped == 0
