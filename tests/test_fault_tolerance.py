"""Fault-tolerance / elasticity: re-mesh restore and straggler mitigation."""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.dataplane import BypassDataplane
from repro.data.pipeline import DataConfig, stream_factory
from repro.models.registry import get_smoke_config

_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.mesh import make_auto_mesh
    from repro.models import lm
    from repro.models.registry import get_smoke_config
    from repro.parallel.axes import AxisRules, axis_rules
    from repro.parallel.specs import make_param_specs, make_shardings

    cfg = get_smoke_config("qwen3-1.7b").replace(param_dtype="float32",
                                                 compute_dtype="float32")
    rules = AxisRules(rules={"batch": ("data",), "fsdp": ("data",),
                             "heads": "model", "ffn": "model",
                             "vocab": "model"})

    def mesh_of(shape):
        return make_auto_mesh(shape, ("data", "model"))

    # "job 1": 2x4 pod slice — init, save
    m1 = mesh_of((2, 4))
    with axis_rules(rules, m1):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        sh1 = make_shardings(make_param_specs(params, rules, m1), m1)
        params = jax.device_put(params, sh1)
    mgr = CheckpointManager("/tmp/elastic_ck")
    mgr.save(7, {"params": params}, block=True)
    ref = jax.tree_util.tree_map(lambda x: np.asarray(x), params)

    # "job 2": node failure -> relaunch on a 4x2 slice; elastic restore
    m2 = mesh_of((4, 2))
    with axis_rules(rules, m2):
        like = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(1)))
        sh2 = make_shardings(make_param_specs(like, rules, m2), m2)
        restored, step, _ = mgr.restore(None, {"params": like},
                                        {"params": sh2})
    assert step == 7
    got = jax.tree_util.tree_map(lambda x: np.asarray(x), restored["params"])
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)
    # shardings really are the new mesh's
    leaf = jax.tree_util.tree_leaves(restored["params"])[0]
    assert leaf.sharding.mesh.shape["data"] == 4
    print("ELASTIC OK")
""")


@pytest.mark.slow  # spawns a fresh 8-device jax process (wall-bound startup)
def test_elastic_remesh_restore():
    """Checkpoint written on a (2,4) slice restores bit-exactly onto a (4,2)
    slice with the new mesh's shardings (node-failure relaunch path)."""
    import shutil
    shutil.rmtree("/tmp/elastic_ck", ignore_errors=True)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _ELASTIC], env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, timeout=600)
    assert "ELASTIC OK" in r.stdout, r.stdout + "\n" + r.stderr


@pytest.mark.slow  # real sleeps/poll deadlines (~10s of wall waiting)
def test_straggler_port_drop_and_refill():
    """A producer port that stalls must not hang the consumer: the poll
    deadline fires, in-flight transfers are dropped, healthy ports keep
    feeding (the drop-and-refill policy from DESIGN.md §2)."""
    cfg = get_smoke_config("qwen3-1.7b")
    dcfg = DataConfig(seq_len=16, global_batch=4, seed=0)

    healthy = stream_factory(cfg, dcfg, n_steps=50)

    def factory(port, n_ports):
        it = healthy(port, n_ports)
        if port == 1:
            def stalling():
                yield next(it)          # one good batch
                time.sleep(30)          # then the node hangs
                yield from it
            return stalling()
        return it

    bp = BypassDataplane(factory, depth=2, ports=2, staging_capacity=2)
    try:
        got = 0
        t0 = time.perf_counter()
        for _ in range(6):
            b = bp.next_batch(timeout_s=5.0)
            assert b is not None
            got += 1
        elapsed = time.perf_counter() - t0
        assert got == 6
        assert elapsed < 25, "stalled port must not serialize the feed"
    finally:
        bp.stop()


def test_checkpoint_survives_torn_write(tmp_path):
    """A crash mid-write leaves a .tmp dir; restore must use the last
    atomic-published step."""
    from repro.checkpoint.manager import CheckpointManager
    import jax.numpy as jnp
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(8.0)}
    mgr.save(1, tree, block=True)
    # simulate a torn step-2 write (no manifest)
    os.makedirs(tmp_path / ".tmp_step_000000002" / "arrays")
    restored, step, _ = mgr.restore(None, tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))
