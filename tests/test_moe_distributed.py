"""Numerical validation of the distributed MoE path (shard_map EP×FP).

Runs in a subprocess with 8 virtual host devices (the device count must be
fixed before jax initializes) and compares apply_moe under a (2,4) mesh —
both weight-gathering and weight-stationary modes — against the single-device
reference computation.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_auto_mesh
    from repro.models import lm
    from repro.models.moe import apply_moe, init_moe_layer, _moe_compute_local
    from repro.models.registry import get_smoke_config
    from repro.parallel.axes import AxisRules, axis_rules

    mesh = make_auto_mesh((2, 4), ("data", "model"))
    rules = AxisRules(rules={"batch": ("data",), "fsdp": ("data",),
                             "experts": "model", "ffn": "model"})

    for arch, cap in (("mixtral-8x7b", 8.0), ("llama4-maverick-400b-a17b", 8.0)):
        cfg = get_smoke_config(arch).replace(
            param_dtype="float32", compute_dtype="float32",
            capacity_factor=cap, d_model=32, d_ff=64)
        p = init_moe_layer(cfg, jax.random.PRNGKey(0), tp_hint=4)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)

        # reference: apply_moe with no mesh (local path incl. shared expert)
        y_ref, aux_ref = jax.jit(lambda p_, x_: apply_moe(cfg, p_, x_))(p, x)

        for force_gather in (True, False):
            os.environ["REPRO_MOE_FORCE_GATHER"] = "1" if force_gather else "0"
            with axis_rules(rules, mesh):
                xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
                ps = jax.tree_util.tree_map(
                    lambda w: jax.device_put(w), p)
                y, aux = jax.jit(lambda p_, x_: apply_moe(cfg, p_, x_))(ps, xs)
            err = float(jnp.abs(y - y_ref).max())
            print(f"{arch} gather={force_gather}: err={err:.2e} "
                  f"aux_err={abs(float(aux)-float(aux_ref)):.2e}")
            assert err < 1e-4, (arch, force_gather, err)
    print("MOE DISTRIBUTED OK")
""")


@pytest.mark.slow  # spawns a fresh 8-device jax process (wall-bound startup)
def test_moe_shard_map_matches_local():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env, cwd=os.path.join(
        os.path.dirname(__file__), ".."), capture_output=True, text=True,
        timeout=600)
    assert "MOE DISTRIBUTED OK" in r.stdout, r.stdout + "\n" + r.stderr
