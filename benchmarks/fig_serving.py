"""LLM serving over the fabric: the ``repro.serving`` application layer.

Three sweeps over the disaggregated prefill/decode cluster (clients →
balancer → 2 prefill → decode replicas, all on one switched fabric):

* **qps** — offered QPS across the prefill replicas' continuous-batching
  capacity knee.  ``us_per_call`` is the p99 TTFT in µs; it fattens
  monotonically with queueing delay as the cluster saturates.
* **kv incast** — the prefill→decode KV-cache transfer as an N:1 elephant
  flow: both prefills converge on a single pinned decode replica through a
  shallow egress port, and the drops land on the *switch* port facing it
  while the NICs stay clean.
* **failover** — kill one decode replica mid-run; requests pinned to it
  strand on the failed node's counters and the rest route around it.

Rows carry completed/sent requests, TTFT/TPOT percentiles and the
attribution counters in ``derived``.
"""
from __future__ import annotations

from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)
from repro.serving import RequestMixConfig, ServingConfig

from .common import emit


def serving(**kw) -> ServingConfig:
    base = dict(
        mix=RequestMixConfig(prompt_mean_tokens=64, prompt_dist="fixed",
                             output_mean_tokens=4, output_dist="fixed"),
        qps=20_000.0, prefill_ns_per_token=200, prefill_overhead_ns=5_000,
        decode_ns_per_token=300, decode_overhead_ns=2_000,
        kv_bytes_per_token=256, kv_segment_bytes=1024,
        max_batch_tokens=2048, max_batch_requests=8)
    base.update(kw)
    return ServingConfig(**base)


def node(name: str, kind: str) -> NodeConfig:
    return NodeConfig(name=name,
                      pool=PoolConfig(n_slots=4096, slot_size=2048),
                      port=PortConfig(n_queues=2, ring_size=512,
                                      writeback_threshold=1),
                      stack=StackConfig(kind=kind, burst_size=32))


def topology(s: ServingConfig, n_clients: int, duration_s: float,
             egress_capacity: int = 256,
             link_gbps: float = 100.0) -> TopologyConfig:
    return TopologyConfig(
        name=f"serving-{s.qps:g}qps",
        nodes=(node("lb", "balancer"), node("prefill0", "prefill"),
               node("prefill1", "prefill"), node("decode0", "decode"),
               node("decode1", "decode")),
        n_clients=n_clients,
        client_pool=PoolConfig(n_slots=4096, slot_size=2048),
        switch=SwitchConfig(egress_capacity=egress_capacity,
                            link=LinkConfig(gbps=link_gbps, latency_ns=1000)),
        traffic=TrafficConfig(duration_s=duration_s, seed=7,
                              mode="open_loop", sim_time=True),
        serving=s)


def run(trial_s: float = 0.002) -> None:
    # offered QPS across the continuous-batching capacity knee
    for qps in (2_000.0, 8_000.0, 24_000.0):
        s = serving(qps=qps, prefill_ns_per_token=2_000)
        rep = run_topology_experiment(topology(s, n_clients=1,
                                               duration_s=trial_s))
        emit(f"serving_qps{qps:g}", rep.extras["ttft_p99_ns"] / 1e3,
             f"done={rep.received}/{rep.sent};"
             f"ttft_p50_us={rep.extras['ttft_p50_ns']/1e3:.1f};"
             f"tpot_p50_us={rep.extras['tpot_p50_ns']/1e3:.1f}")
    # KV elephant incast: 2 prefills -> 1 pinned decode, shallow egress
    s = serving(kv_bytes_per_token=4096, decode=("decode0",))
    rep = run_topology_experiment(topology(s, n_clients=2, duration_s=trial_s,
                                           egress_capacity=16,
                                           link_gbps=10.0))
    emit("serving_kv_incast", rep.extras["ttft_p99_ns"] / 1e3,
         f"done={rep.received}/{rep.sent};"
         f"sw_drops={int(rep.extras['sw_p3_egress_drops'])};"
         f"imissed={int(rep.extras['n3_imissed'])};"
         f"reasm_stuck={int(rep.extras['n3_decode_reasm_pending'])}")
    # decode failover at mid-run
    s = serving(fail_node="decode1", fail_at_s=trial_s / 4)
    rep = run_topology_experiment(topology(s, n_clients=2,
                                           duration_s=trial_s))
    lost = int(rep.extras["n4_decode_failed_drops"]
               + rep.extras["n4_decode_stranded_requests"])
    emit("serving_failover", rep.extras["ttft_p99_ns"] / 1e3,
         f"done={rep.received}/{rep.sent};lost_at_failed={lost};"
         f"healthy_done={int(rep.extras['n3_decode_requests_done'])}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
