"""Multi-host scenarios on the Switch/Topology layer.

Two benchmarks the single-host loopback harness could never express:

* **forward** — one client and one server node on opposite switch ports,
  client→server→client RTT vs offered rate.  The RTT floor is four wire
  crossings (uplink + egress, each way); the knee appears as the offered
  rate approaches the fabric's line rate.
* **incast** — N clients converge on one server (the classic N:1 pattern).
  The switch egress port facing the server saturates first: the RTT tail
  fattens with client count, and every loss is a switch egress-buffer drop
  (``sw_p0_egress_drops``) while the server NIC stays clean (``imissed`` /
  ``rx_nombuf`` == 0) — the loss-attribution split a single-NIC model
  cannot produce.

Rows: ``us_per_call`` is the p99 RTT in µs; ``derived`` carries achieved
aggregate Gbps, drop counts and egress-buffer high water.
"""
from __future__ import annotations

from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)

from .common import emit


def topology(n_clients: int, rate_gbps: float, duration_s: float,
             egress_capacity: int = 32, link_gbps: float = 10.0) -> TopologyConfig:
    """One server node + N fabric-attached clients around one switch."""
    return TopologyConfig(
        name=f"incast-{n_clients}x{rate_gbps:g}",
        nodes=(NodeConfig(name="server", pool=PoolConfig(n_slots=16384),
                          port=PortConfig(ring_size=2048,
                                          writeback_threshold=1),
                          stack=StackConfig(kind="bypass", burst_size=64)),),
        n_clients=n_clients,
        switch=SwitchConfig(egress_capacity=egress_capacity,
                            link=LinkConfig(gbps=link_gbps, latency_ns=1000)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=rate_gbps,
                              packet_size=1518, duration_s=duration_s,
                              seed=7))


def run(trial_s: float = 0.0004) -> None:
    # client -> server forward path: RTT vs offered rate on a 10 GbE fabric
    for rate in (1.0, 4.0, 8.0):
        rep = run_topology_experiment(topology(1, rate, trial_s))
        lat = rep.latency
        emit(f"incast_forward_r{rate:g}", lat.p99_ns / 1e3,
             f"gbps={rep.achieved_gbps:.2f};med_us={lat.median_ns/1e3:.1f};"
             f"drops={rep.dropped}")
    # N:1 incast: fixed 3 Gbps per client into one 10 GbE egress port
    for n in (1, 2, 4, 8):
        rep = run_topology_experiment(topology(n, 3.0, trial_s))
        lat = rep.latency
        emit(f"incast_c{n}", lat.p99_ns / 1e3,
             f"gbps={rep.achieved_gbps:.2f};sw_drops="
             f"{int(rep.extras['sw_p0_egress_drops'])};occ_high="
             f"{int(rep.extras['sw_p0_occ_high'])};imissed="
             f"{int(rep.extras['n0_imissed'])};drop_pct={rep.drop_pct:.1f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
