"""EtherLoadGen §3.3 statistics table: per-packet RTT distributions.

Mean / median / std / p95 / p99 / p99.9 / max latency and drop % for both
stacks at fixed offered loads — the 'statistics file' the paper's loadgen
produces.  Each (stack, rate) cell is one declarative open-loop experiment.
"""
from __future__ import annotations

from repro.exp import TrafficConfig, run_experiment

from .common import emit, experiment_config


def run(duration_s: float = 0.05) -> dict:
    out = {}
    for stack in ("bypass", "kernel"):
        for rate in (0.25, 0.5, 1.0):
            cfg = experiment_config(
                stack,
                traffic=TrafficConfig(mode="open_loop", rate_gbps=rate,
                                      packet_size=1518, duration_s=duration_s),
                name=f"tbl-latency-{stack}-{rate}")
            rep = run_experiment(cfg)
            s = rep.latency
            if s is None:
                continue
            out[(stack, rate)] = rep
            emit(f"tbl_latency_{stack}_{rate}gbps", s.mean_ns / 1e3,
                 f"med_us={s.median_ns/1e3:.1f};p99_us={s.p99_ns/1e3:.1f};"
                 f"p999_us={s.p999_ns/1e3:.1f};drop_pct={rep.drop_pct:.3f};"
                 f"achieved_gbps={rep.achieved_gbps:.3f}")
    return out


if __name__ == "__main__":
    run()
