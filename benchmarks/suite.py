"""Declarative sweep grids → frozen configs → (optionally parallel) trials.

A *grid* is one config template plus axes of values; :func:`expand_grid`
freezes the cartesian product into :class:`Trial`\\ s (plain config dicts —
the only thing that crosses a process boundary).  :func:`run_suite` executes
them serially or across a ``ProcessPoolExecutor`` and merges the per-trial
:class:`~repro.core.telemetry.RunReport`\\ s into one JSON-able artifact.

Determinism is the whole point:

* every trial is keyed by the sha256 of its canonical ``{kind, config}``
  JSON (:func:`trial_key`) — that key names its result-cache entry, so a
  re-run only executes trials whose exact config changed;
* per-client RNG seeds derive from config *content* (``repro.exp.seeding``),
  never from submission order, and replicates get their seeds the same way
  (:func:`with_replicates`);
* the merged artifact is assembled in trial-definition order and carries no
  wall-clock fields, so any submission order — shuffled, sharded, parallel —
  produces a byte-identical file (timing travels separately).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from concurrent.futures import ProcessPoolExecutor, as_completed
from dataclasses import dataclass
from itertools import product
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.exp import (ExperimentConfig, TopologyConfig, TrafficConfig,
                       config_fingerprint, derive_seed, run_experiment,
                       run_topology_experiment)

from .common import experiment_config

TRIAL_KINDS = ("experiment", "topology")


@dataclass(frozen=True)
class Trial:
    """One frozen unit of work: a config dict plus which runner drives it."""

    name: str
    kind: str  # "experiment" (single-host) | "topology" (multi-host)
    config: Dict[str, Any]


def _canonical(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def trial_key(trial: Trial) -> str:
    """Content address of one trial: sha256 over the exact ``{kind, config}``
    JSON — the config's seed and every physics knob included, so two trials
    share a key (and a cache entry) only when they are the same run."""
    return hashlib.sha256(
        _canonical({"kind": trial.kind, "config": trial.config})
        .encode("utf-8")).hexdigest()


def set_axis(cfg_dict: Dict[str, Any], path: str, value: Any) -> None:
    """Set ``path`` (dotted, e.g. ``"traffic.rate_gbps"``) in a nested config
    dict.  A missing segment raises KeyError — a typo'd axis must not
    silently sweep nothing."""
    parts = path.split(".")
    d = cfg_dict
    for p in parts[:-1]:
        if not isinstance(d, dict) or p not in d:
            raise KeyError(f"axis path {path!r}: no key {p!r} in config")
        d = d[p]
    if not isinstance(d, dict) or parts[-1] not in d:
        raise KeyError(f"axis path {path!r}: no key {parts[-1]!r} in config")
    d[parts[-1]] = value


Axis = Tuple[str, Sequence[Any]]  # (dotted path, values) [+ optional labels]


def expand_grid(name: str, kind: str, template: Dict[str, Any],
                axes: Sequence[Sequence[Any]]) -> List[Trial]:
    """Cartesian product of ``axes`` over one config template, in definition
    order (first axis slowest).  Each axis is ``(path, values)`` or
    ``(path, values, labels)``; labels name the trial when a value has no
    short repr (e.g. a whole ``ports`` list)."""
    if kind not in TRIAL_KINDS:
        raise ValueError(f"kind must be one of {TRIAL_KINDS}, got {kind!r}")
    paths, value_lists, label_lists = [], [], []
    for ax in axes:
        path, values = ax[0], list(ax[1])
        labels = list(ax[2]) if len(ax) > 2 else [str(v) for v in values]
        if len(labels) != len(values):
            raise ValueError(f"axis {path!r}: {len(values)} values but "
                             f"{len(labels)} labels")
        paths.append(path)
        value_lists.append(values)
        label_lists.append(labels)
    trials: List[Trial] = []
    for combo in product(*(range(len(v)) for v in value_lists)):
        cfg = json.loads(json.dumps(template))  # deep, JSON-clean copy
        tags = []
        for path, vi, values, labels in zip(paths, combo, value_lists,
                                            label_lists):
            set_axis(cfg, path, values[vi])
            tags.append(f"{path.rsplit('.', 1)[-1]}={labels[vi]}")
        trial_name = f"{name}/{','.join(tags)}" if tags else name
        if "name" in cfg:
            cfg["name"] = trial_name
        trials.append(Trial(name=trial_name, kind=kind, config=cfg))
    names = [t.name for t in trials]
    if len(set(names)) != len(names):
        raise ValueError(f"grid {name!r} produced duplicate trial names")
    return trials


def with_replicates(trials: Sequence[Trial], n: int) -> List[Trial]:
    """Each trial × ``n`` seed-replicates.  Replicate 0 is the trial itself;
    replicate r ≥ 1 re-seeds ``traffic.seed`` from the trial config's
    content fingerprint — stable under reordering, decorrelated across
    replicates and across distinct trials."""
    out: List[Trial] = []
    for t in trials:
        out.append(Trial(name=f"{t.name}@r0", kind=t.kind, config=t.config))
        fp = config_fingerprint(t.config)
        for r in range(1, n):
            cfg = json.loads(json.dumps(t.config))
            cfg.setdefault("traffic", {})
            cfg["traffic"]["seed"] = derive_seed(fp, r, "replicate")
            out.append(Trial(name=f"{t.name}@r{r}", kind=t.kind, config=cfg))
    return out


def _run_trial(payload: Tuple[str, str]) -> Dict[str, Any]:
    """Worker entry point (module-level: must pickle by reference).  Takes
    ``(kind, config_json)``, returns the RunReport as plain data."""
    kind, cfg_json = payload
    cfg_dict = json.loads(cfg_json)
    if kind == "topology":
        rep = run_topology_experiment(TopologyConfig.from_dict(cfg_dict))
    elif kind == "experiment":
        rep = run_experiment(ExperimentConfig.from_dict(cfg_dict))
    else:
        raise ValueError(f"unknown trial kind {kind!r}")
    return rep.to_dict()


def _cache_path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"{key}.json")


def _cache_load(cache_dir: str, key: str) -> Optional[Dict[str, Any]]:
    try:
        with open(_cache_path(cache_dir, key)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _cache_store(cache_dir: str, key: str, report: Dict[str, Any]) -> None:
    os.makedirs(cache_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(report, f, sort_keys=True)
        os.replace(tmp, _cache_path(cache_dir, key))  # atomic vs. racers
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def run_suite(trials: Sequence[Trial], workers: int = 1,
              cache_dir: Optional[str] = None,
              submit_order: Optional[Sequence[int]] = None,
              ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Execute every trial; return ``(merged, timing)``.

    ``merged`` maps trial name → ``{kind, config, report}`` in *definition*
    order and contains nothing wall-clock-dependent: shuffling
    ``submit_order``, changing ``workers``, or re-running from a warm
    ``cache_dir`` all produce the identical object.  ``timing`` carries the
    wall-clock facts (workers, wall seconds, trials/s, cache hits) for
    benchmark artifacts."""
    trials = list(trials)
    names = [t.name for t in trials]
    if len(set(names)) != len(names):
        raise ValueError("duplicate trial names in suite")
    order = list(range(len(trials))) if submit_order is None \
        else list(submit_order)
    if sorted(order) != list(range(len(trials))):
        raise ValueError("submit_order must be a permutation of the trials")
    keys = [trial_key(t) for t in trials]
    results: Dict[int, Dict[str, Any]] = {}
    cache_hits = 0
    t0 = time.perf_counter()  # simlint: disable=SL001 -- bench wall timing
    todo: List[int] = []
    for i in order:
        cached = _cache_load(cache_dir, keys[i]) if cache_dir else None
        if cached is not None:
            results[i] = cached
            cache_hits += 1
        else:
            todo.append(i)
    payloads = {i: (trials[i].kind, _canonical(trials[i].config))
                for i in todo}
    if workers <= 1 or len(todo) <= 1:
        for i in todo:
            results[i] = _run_trial(payloads[i])
    else:
        with ProcessPoolExecutor(max_workers=workers) as ex:
            futs = {ex.submit(_run_trial, payloads[i]): i for i in todo}
            for fut in as_completed(futs):
                results[futs[fut]] = fut.result()
    if cache_dir:
        for i in todo:
            _cache_store(cache_dir, keys[i], results[i])
    wall_s = time.perf_counter() - t0  # simlint: disable=SL001 -- bench wall timing
    merged = {t.name: {"kind": t.kind, "config": t.config,
                       "report": results[i]}
              for i, t in enumerate(trials)}
    timing = {"workers": workers, "n_trials": len(trials),
              "n_cache_hits": cache_hits, "wall_s": wall_s,
              "trials_per_s": (len(trials) / wall_s) if wall_s > 0 else 0.0}
    return merged, timing


def write_suite_json(path: str, merged: Dict[str, Any]) -> None:
    """Serialize a merged suite byte-stably (sorted keys, fixed separators,
    trailing newline)."""
    with open(path, "w") as f:
        json.dump(merged, f, sort_keys=True, indent=2)
        f.write("\n")


# -- predefined grids ---------------------------------------------------------

def fig3a_grid(trial_s: float = 0.002) -> List[Trial]:
    """The Fig. 3(a) sweep as a parallel suite: MSB search over stack kind ×
    NIC-port count (the grid ``benchmarks/parallel_bench.py`` times)."""
    base = experiment_config(
        "bypass",
        traffic=TrafficConfig(mode="msb", trial_s=trial_s, refine_iters=2,
                              start_gbps=0.1),
        name="fig3a-grid").to_dict()
    port = base["ports"][0]
    return expand_grid("fig3a-grid", "experiment", base, [
        ("stack.kind", ["bypass", "kernel"]),
        ("ports", [[dict(port)] * n for n in (1, 2, 3, 4)],
         ["1", "2", "3", "4"]),
    ])


NAMED_GRIDS = {"fig3a-grid": fig3a_grid}


def named_grid(name: str, trial_s: float = 0.002) -> List[Trial]:
    if name not in NAMED_GRIDS:
        raise ValueError(
            f"unknown grid {name!r}; available: {sorted(NAMED_GRIDS)}")
    return NAMED_GRIDS[name](trial_s=trial_s)
