"""Shared benchmark plumbing: setup factories and CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

from repro.core import (BypassL2FwdServer, KernelStackServer, LoadGen,
                        PacketPool, Port, TrafficPattern,
                        find_max_sustainable_bandwidth)
from repro.core.cost import HostCostModel

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    line = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def make_setup(stack: str, nports: int = 1, ring: int = 1024,
               writeback_threshold: int = 32, burst: int = 64,
               pool_slots: int = 16384,
               cost: Optional[HostCostModel] = None,
               sockbuf_budget: int = 16,
               n_queues: int = 1,
               n_lcores: Optional[int] = None) -> Callable:
    """Returns a fresh-state factory for MSB searches / timed runs."""

    def factory() -> Tuple[object, List[Port]]:
        pool = PacketPool(pool_slots, 1518)
        ports = [Port.make(pool, ring_size=ring,
                           writeback_threshold=writeback_threshold,
                           n_queues=n_queues)
                 for _ in range(nports)]
        if stack == "bypass":
            return BypassL2FwdServer(ports, burst_size=burst,
                                     n_lcores=n_lcores), ports
        return KernelStackServer(ports, cost_model=cost or HostCostModel(),
                                 sockbuf_budget=sockbuf_budget,
                                 n_lcores=n_lcores), ports

    return factory


def msb(stack: str, trial_s: float = 0.12, **kw) -> Tuple[float, float]:
    """(max sustainable Gbps, us per packet at that rate)."""
    f = make_setup(stack, **kw)
    gbps, reports = find_max_sustainable_bandwidth(
        f, trial_s=trial_s, refine_iters=4, start_gbps=0.1)
    good = [r for r in reports if r.drop_pct == 0 and r.received > 0]
    us_per_pkt = 0.0
    if good:
        best = max(good, key=lambda r: r.achieved_gbps)
        if best.achieved_mpps > 0:
            us_per_pkt = 1.0 / best.achieved_mpps
    return gbps, us_per_pkt
