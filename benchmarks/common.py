"""Shared benchmark plumbing: experiment-config builders and CSV emission.

Every benchmark testbed is described by a :class:`repro.exp.ExperimentConfig`
and built/driven by :func:`repro.exp.run_experiment` — no hand-wired
pool/ring/server setup anywhere in ``benchmarks/``.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from repro.exp import (CostConfig, ExperimentConfig, PoolConfig, PortConfig,
                       StackConfig, TrafficConfig, make_server_factory,
                       run_experiment)

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    line = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def experiment_config(stack: str, nports: int = 1, ring: int = 1024,
                      writeback_threshold: Optional[int] = 32, burst: int = 64,
                      pool_slots: int = 16384,
                      cost: Optional[CostConfig] = None,
                      sockbuf_budget: int = 16,
                      n_queues: int = 1,
                      n_lcores: Optional[int] = None,
                      traffic: Optional[TrafficConfig] = None,
                      name: str = "bench") -> ExperimentConfig:
    """The one place benchmark knobs map onto the declarative config tree."""
    return ExperimentConfig(
        name=name,
        pool=PoolConfig(n_slots=pool_slots, slot_size=1518),
        ports=tuple(PortConfig(n_queues=n_queues, ring_size=ring,
                               writeback_threshold=writeback_threshold)
                    for _ in range(nports)),
        stack=StackConfig(kind=stack, burst_size=burst, n_lcores=n_lcores,
                          sockbuf_budget=sockbuf_budget, cost=cost),
        traffic=traffic if traffic is not None else TrafficConfig(),
    )


def make_setup(stack: str, **kw) -> Callable[[], Tuple[object, List[object]]]:
    """Fresh-state ``() -> (server, devs)`` factory for timed runs."""
    return make_server_factory(experiment_config(stack, **kw))


def msb(stack: str, trial_s: float = 0.004, **kw) -> Tuple[float, float]:
    """(max sustainable Gbps, us per packet at the best sustainable rate)."""
    cfg = experiment_config(
        stack,
        traffic=TrafficConfig(mode="msb", trial_s=trial_s, refine_iters=4,
                              start_gbps=0.1),
        **kw)
    rep = run_experiment(cfg)
    gbps = rep.extras.get("msb_gbps", 0.0)
    us_per_pkt = 1.0 / rep.achieved_mpps if rep.achieved_mpps > 0 else 0.0
    return gbps, us_per_pkt
