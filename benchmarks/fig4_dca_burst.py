"""Paper Fig. 4 / §5.2: DCA sensitivity to the L2Fwd burst size — measured
end-to-end through the sim-time descriptor path.

Burst sizes {1, 32, 1024} run the *real* virtual-time dataplane
(``run_experiment`` with a :class:`~repro.exp.DcaConfig`): NIC delivery goes
through the RX descriptor rings, completions publish at writeback-threshold
crossings or when the writeback-timeout (ITR analogue) event fires on the
``EventScheduler``, and the bypass PMD accumulates a full burst of
written-back descriptors before forwarding.  The observable is the paper's:
the measured RTT percentiles — forwarding in bursts of 32 overlaps DMA with
processing, while waiting for 1024 packets floods the staging path and
fattens p50/p99 — plus the per-ring writeback telemetry
(``p0q0_writebacks`` / ``wb_size_mean`` / ``timeout_flushes``) now merged
into every :class:`~repro.core.RunReport`.

The legacy standalone queue-occupancy proxy survives as the `occupancy=`
columns (``repro.core.dca.run_burst_experiment``), so both views of the same
mechanism print side by side.
"""
from __future__ import annotations

from repro.core.dca import run_burst_experiment
from repro.exp import (DcaConfig, ExperimentConfig, PortConfig, StackConfig,
                       TrafficConfig, run_experiment)

from .common import emit

BURSTS = (1, 32, 1024)
WRITEBACK_THRESHOLD = 32
WRITEBACK_TIMEOUT_NS = 200_000


def config(burst: int, duration_s: float = 0.004) -> ExperimentConfig:
    return ExperimentConfig(
        name=f"fig4-burst-{burst}",
        ports=(PortConfig(n_queues=1, ring_size=2048),),
        stack=StackConfig(kind="bypass", n_lcores=1),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=10.0,
                              packet_size=1518, duration_s=duration_s,
                              seed=3),
        dca=DcaConfig(burst_size=burst,
                      writeback_threshold=WRITEBACK_THRESHOLD,
                      writeback_timeout_ns=WRITEBACK_TIMEOUT_NS))


def run(duration_s: float = 0.004) -> dict:
    out = {}
    for burst in BURSTS:
        rep = run_experiment(config(burst, duration_s))
        lat = rep.latency
        out[burst] = dict(
            p50_us=lat.median_ns / 1e3, p99_us=lat.p99_ns / 1e3,
            max_us=lat.max_ns / 1e3, drop_pct=rep.drop_pct,
            writebacks=rep.extras["p0q0_writebacks"],
            wb_size_mean=rep.extras["p0q0_wb_size_mean"],
            timeout_flushes=rep.extras["p0q0_timeout_flushes"],
        )
        # side-by-side: the legacy staging-occupancy proxy for the same burst
        trace, delay = run_burst_experiment(
            n_packets=1024, burst_size=burst,
            writeback_threshold=WRITEBACK_THRESHOLD)
        d = delay[delay >= 0]
        emit(f"fig4_burst_{burst}", lat.p99_ns / 1e3,
             f"p50_us={lat.median_ns/1e3:.1f};p99_us={lat.p99_ns/1e3:.1f};"
             f"rx={rep.received}/{rep.sent};"
             f"writebacks={rep.extras['p0q0_writebacks']:.0f};"
             f"wb_mean={rep.extras['p0q0_wb_size_mean']:.1f};"
             f"timeout_flushes={rep.extras['p0q0_timeout_flushes']:.0f};"
             f"occupancy_high_water={trace.high_water};"
             f"occupancy_pressure={trace.pressure():.3f};"
             f"proxy_delay={float(d.mean()) if len(d) else 0.0:.0f}")
    return out


if __name__ == "__main__":
    run()
