"""Paper Fig. 4 / §5.2: DCA sensitivity to the L2Fwd burst size.

1024 packets arrive in a short interval; the server forwards in bursts of
{32 .. 1024}.  We report the staging-queue analogues of the paper's LLC
writeback metrics: occupancy high-water mark, mean occupancy, pressure (time
above half capacity), mean queue delay, and descriptor-writeback burst sizes.
"""
from __future__ import annotations

import numpy as np

from repro.core.dca import run_burst_experiment

from .common import emit


def run() -> dict:
    out = {}
    for burst in (32, 64, 128, 256, 512, 1024):
        trace, delay = run_burst_experiment(
            n_packets=1024, burst_size=burst, writeback_threshold=32)
        d = delay[delay >= 0]
        out[burst] = dict(high_water=trace.high_water, mean_occ=trace.mean,
                          pressure=trace.pressure(),
                          mean_delay=float(d.mean()) if len(d) else 0.0)
        emit(f"fig4_burst_{burst}", float(d.mean()) if len(d) else 0.0,
             f"high_water={trace.high_water};mean_occ={trace.mean:.1f};"
             f"pressure={trace.pressure():.3f}")
    return out


if __name__ == "__main__":
    run()
