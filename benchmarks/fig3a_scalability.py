"""Paper Fig. 3(a): maximum sustainable bandwidth vs. #NIC ports,
Linux-kernel stack (iperf analogue) vs. DPDK bypass stack (L2Fwd analogue).

Paper's claims to reproduce: (1) bypass ≫ kernel at every port count
(5.4×/4.9× at 1/4 NICs in the paper); (2) bypass retains its advantage as
ports scale.  NOTE: this container has ONE core, so aggregate scaling with
ports is GIL-bound for both stacks; the per-stack RATIO is the reproduced
quantity (see EXPERIMENTS.md).
"""
from __future__ import annotations

from .common import emit, msb


def run(trial_s: float = 0.12) -> dict:
    out = {}
    for nports in (1, 2, 3, 4):
        b_gbps, b_us = msb("bypass", trial_s=trial_s, nports=nports)
        k_gbps, k_us = msb("kernel", trial_s=trial_s, nports=nports)
        ratio = b_gbps / k_gbps if k_gbps > 0 else float("inf")
        out[nports] = (b_gbps, k_gbps, ratio)
        emit(f"fig3a_bypass_{nports}port", b_us, f"msb_gbps={b_gbps:.3f}")
        emit(f"fig3a_kernel_{nports}port", k_us, f"msb_gbps={k_gbps:.3f}")
        emit(f"fig3a_ratio_{nports}port", 0.0, f"bypass_over_kernel={ratio:.2f}")
    return out


if __name__ == "__main__":
    run()
