"""Paper Fig. 3(a): maximum sustainable bandwidth vs. #NIC ports AND #cores,
Linux-kernel stack (iperf analogue) vs. DPDK bypass stack (L2Fwd analogue).

Paper's claims to reproduce: (1) bypass ≫ kernel at every port count
(5.4×/4.9× at 1/4 NICs in the paper); (2) bypass retains its advantage as
ports scale; (3) bandwidth scales with the number of cores, each core
polling its own RSS-steered NIC queue.  NOTE: this container has ONE core,
so aggregate scaling with ports/lcores is GIL-bound for both stacks; the
per-stack RATIO and the per-queue balance are the reproduced quantities
(see EXPERIMENTS.md).

All testbeds are declared as :class:`repro.exp.ExperimentConfig` and built
through the EthDev facade.
"""
from __future__ import annotations

from repro.exp import Testbed, TrafficConfig, run_testbed

from .common import emit, experiment_config, msb


def _queue_balance(n_lcores: int, n_queues: int,
                   n_packets: int = 4000) -> tuple:
    """Closed-loop run on 1 port × n_queues × n_lcores; returns
    (rss_imbalance, per-queue rx counts) for the cores×queues sweep."""
    cfg = experiment_config(
        "bypass", n_queues=n_queues, n_lcores=n_lcores,
        traffic=TrafficConfig(mode="closed_loop", n_packets=n_packets,
                              packet_size=512, window=256, payload_seed=0),
        name=f"fig3a-balance-{n_lcores}x{n_queues}")
    tb = Testbed.build(cfg)
    rep = run_testbed(tb)
    assert rep.received == n_packets, "balance run must conserve packets"
    per_queue = [s.rx_packets
                 for _, s in sorted(tb.server.per_queue_stats().items())]
    imb = rep.extras.get("p0_rss_imbalance", 1.0)
    return imb, per_queue


def run(trial_s: float = 0.004) -> dict:
    out = {}
    # -- port-count axis (the seed sweep) ------------------------------------
    for nports in (1, 2, 3, 4):
        b_gbps, b_us = msb("bypass", trial_s=trial_s, nports=nports)
        k_gbps, k_us = msb("kernel", trial_s=trial_s, nports=nports)
        ratio = b_gbps / k_gbps if k_gbps > 0 else float("inf")
        out[nports] = (b_gbps, k_gbps, ratio)
        emit(f"fig3a_bypass_{nports}port", b_us, f"msb_gbps={b_gbps:.3f}")
        emit(f"fig3a_kernel_{nports}port", k_us, f"msb_gbps={k_gbps:.3f}")
        emit(f"fig3a_ratio_{nports}port", 0.0, f"bypass_over_kernel={ratio:.2f}")
    # -- cores×queues axis (multi-queue RSS NIC, one lcore per queue) --------
    for n_lcores, n_queues in ((1, 1), (2, 2), (4, 4)):
        b_gbps, b_us = msb("bypass", trial_s=trial_s, nports=1,
                           n_queues=n_queues, n_lcores=n_lcores)
        k_gbps, k_us = msb("kernel", trial_s=trial_s, nports=1,
                           n_queues=n_queues, n_lcores=n_lcores)
        imb, per_queue = _queue_balance(n_lcores, n_queues)
        out[(n_lcores, n_queues)] = (b_gbps, k_gbps, imb)
        emit(f"fig3a_bypass_{n_lcores}core_{n_queues}q", b_us,
             f"msb_gbps={b_gbps:.3f}")
        emit(f"fig3a_kernel_{n_lcores}core_{n_queues}q", k_us,
             f"msb_gbps={k_gbps:.3f}")
        emit(f"fig3a_balance_{n_lcores}core_{n_queues}q", 0.0,
             f"rss_imbalance={imb:.3f};per_queue_rx="
             + "/".join(str(c) for c in per_queue))
    return out


if __name__ == "__main__":
    run()
