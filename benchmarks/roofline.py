"""Roofline table generator: reads dry-run JSONL records and emits the
per-(arch × shape × mesh) three-term roofline table for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from typing import Dict, List, Optional

from .common import emit

_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
# prefer the post-hillclimb fleet; fall back to the paper-faithful baseline
DEFAULT_PATH = (os.path.join(_RESULTS, "dryrun_final.jsonl")
                if os.path.exists(os.path.join(_RESULTS, "dryrun_final.jsonl"))
                else os.path.join(_RESULTS, "dryrun_baseline.jsonl"))


def load_records(path: str = DEFAULT_PATH) -> List[dict]:
    if not os.path.exists(path):
        return []
    # keep only the LAST record per (arch, shape, mesh) — reruns append
    by_cell: "OrderedDict[tuple, dict]" = OrderedDict()
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            r = json.loads(line)
            by_cell[(r["arch"], r["shape"], r["mesh"])] = r
    return list(by_cell.values())


def markdown_table(recs: List[dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | status | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | useful_flops | mfu_ub |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            rows.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                        f"{reason} | | | | | | |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {ro['t_compute_s']:.3f} | {ro['t_memory_s']:.3f} "
            f"| {ro['t_collective_s']:.3f} | {ro['bottleneck']} "
            f"| {ro['useful_flops_ratio']:.3f} | {ro['mfu_upper_bound']:.3f} |")
    return "\n".join(rows)


def run(path: str = DEFAULT_PATH) -> None:
    recs = load_records(path)
    if not recs:
        emit("roofline_table", 0.0, "no dry-run records found; run "
             "launch_dryrun_all.sh first")
        return
    n_ok = sum(1 for r in recs if r["status"] == "ok")
    n_skip = sum(1 for r in recs if r["status"] == "skipped")
    n_err = len(recs) - n_ok - n_skip
    emit("roofline_cells", 0.0, f"ok={n_ok};skipped={n_skip};errors={n_err}")
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        ro = r["roofline"]
        emit(f"roofline_{r['arch']}_{r['shape']}",
             ro["step_time_lower_bound"] * 1e6 if "step_time_lower_bound" in ro
             else max(ro["t_compute_s"], ro["t_memory_s"],
                      ro["t_collective_s"]) * 1e6,
             f"bottleneck={ro['bottleneck']};"
             f"t_comp={ro['t_compute_s']:.3f};t_mem={ro['t_memory_s']:.3f};"
             f"t_coll={ro['t_collective_s']:.3f};"
             f"useful={ro['useful_flops_ratio']:.3f};"
             f"mfu_ub={ro['mfu_upper_bound']:.3f}")


if __name__ == "__main__":
    print(markdown_table(load_records()))
