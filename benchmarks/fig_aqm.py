"""AQM policy comparison under N:1 incast (drop-tail vs RED vs ECN+DCTCP).

Three fabrics, same 8-client incast into one 10 GbE egress port:

* **drop-tail** — the PR-6 baseline: the egress buffer fills and every
  loss is a tail drop at the moment of overflow.
* **red** — probabilistic early drop on the RED curve: losses start below
  the buffer ceiling, signaling senders (here: the DCTCP controller, via
  inferred losses) before the queue slams into the wall.
* **ecn+dctcp** — the same curve applied as CE marks instead of drops,
  echoed back by the server and consumed by the DCTCP-style rate
  controller (virtual-time windows, multiplicative decrease by alpha/2,
  additive fast-recovery increase, in-flight cap as the cwnd analogue).

The headline row contrast: drop-tail sustains line rate by discarding
over half the offered frames; ECN+DCTCP converges the eight clients onto
the fair share — ``>=90%`` of line rate with the egress drop counter at
(or within 10x of) zero.

Rows: ``us_per_call`` is the p99 RTT in µs; ``derived`` carries achieved
aggregate Gbps, egress drops, CE marks, and early (AQM) drops.
"""
from __future__ import annotations

from typing import Optional

from repro.exp import (AqmConfig, LinkConfig, NodeConfig, PipelineConfig,
                       PoolConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)

from .common import emit

N_CLIENTS = 8
RATE_GBPS = 3.0          # per client: 24 Gbps offered into a 10 GbE egress
LINK_GBPS = 10.0


def topology(aqm_kind: str, duration_s: float,
             cc_mode: str = "fixed") -> TopologyConfig:
    """8 clients x 3 Gbps into one 10 GbE server port, AQM per ``aqm_kind``."""
    pipeline: Optional[PipelineConfig] = None
    if aqm_kind != "drop-tail":
        pipeline = PipelineConfig(aqm=AqmConfig(
            kind=aqm_kind, min_thresh=8, max_thresh=24, max_p=0.1, seed=1))
    return TopologyConfig(
        name=f"aqm-{aqm_kind}-{cc_mode}",
        nodes=(NodeConfig(name="server", pool=PoolConfig(n_slots=16384)),),
        n_clients=N_CLIENTS,
        client_pool=PoolConfig(n_slots=16384),
        switch=SwitchConfig(egress_capacity=64,
                            link=LinkConfig(gbps=LINK_GBPS, latency_ns=1000),
                            pipeline=pipeline),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=RATE_GBPS,
                              packet_size=1518, duration_s=duration_s,
                              seed=7, cc_mode=cc_mode,
                              cc_window_ns=100_000, cc_increase_gbps=0.1,
                              cc_max_inflight=8))


def run(trial_s: float = 0.005) -> None:
    for kind, cc in (("drop-tail", "fixed"), ("red", "dctcp"),
                     ("ecn", "dctcp")):
        rep = run_topology_experiment(topology(kind, trial_s, cc_mode=cc))
        ex = rep.extras
        emit(f"aqm_{kind}" + ("_dctcp" if cc == "dctcp" else ""),
             rep.latency.p99_ns / 1e3,
             f"gbps={rep.achieved_gbps:.2f};"
             f"line_frac={rep.achieved_gbps / LINK_GBPS:.3f};"
             f"sw_drops={int(ex['sw_p0_egress_drops'])};"
             f"early_drops={int(ex.get('sw_p0_aqm_early_drops', 0))};"
             f"marked={int(ex.get('sw_p0_ecn_marked', 0))};"
             f"drop_pct={rep.drop_pct:.1f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
