"""Kernel microbenchmarks: wall-clock of the jitted production (chunked-jnp)
paths on CPU, plus flops-based derived throughput.  The Pallas kernels target
TPU (interpret mode is a correctness harness, not a benchmark) — their roofline
behaviour is captured by the dry-run analysis instead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import emit


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()  # simlint: disable=SL001 -- bench wall timing
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us  # simlint: disable=SL001 -- bench wall timing


def run() -> None:
    rng = np.random.default_rng(0)

    # flash attention (chunked), causal 2k
    B, S, H, Hkv, Dh = 1, 2048, 8, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="chunked"))
    us = _time(f, q, k, v)
    flops = 4.0 * B * S * S * H * Dh
    emit("kernel_flash_attn_2k", us, f"gflops_s={flops/us/1e3:.1f}")

    # decode attention, 32k cache
    S = 32768
    q1 = jnp.asarray(rng.normal(size=(4, H, Dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(4, S, Hkv, Dh)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(size=(4, S, Hkv, Dh)), jnp.bfloat16)
    cl = jnp.full((4,), S, jnp.int32)
    f = jax.jit(lambda q, k, v, c: ops.decode_attention(q, k, v, c,
                                                        impl="chunked"))
    us = _time(f, q1, kc, vc, cl)
    bytes_ = kc.nbytes + vc.nbytes
    emit("kernel_decode_attn_32k", us, f"gb_s={bytes_/us/1e3:.2f}")

    # rg-lru associative scan
    B, S, W = 2, 4096, 1024
    x = jnp.asarray(rng.normal(size=(B, S, W)), jnp.float32)
    al = jnp.asarray(-np.abs(rng.normal(size=(B, S, W))) * 0.3, jnp.float32)
    f = jax.jit(lambda x, a: ops.rglru_scan(x, a, impl="chunked")[0])
    us = _time(f, x, al)
    emit("kernel_rglru_4k", us, f"melem_s={B*S*W/us:.1f}")

    # mamba2 ssd
    B, S, H, P, N = 1, 4096, 16, 64, 64
    xs = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) * 0.3 + 0.01,
                     jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(H,))) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    f = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=128, impl="chunked")[0])
    us = _time(f, xs, dt, A, Bm, Cm)
    emit("kernel_ssd_4k", us, f"mtok_s={B*S/us:.2f}")

    # burst gather
    arena = jnp.asarray(rng.integers(0, 256, size=(4096, 1518)), jnp.uint8)
    slots = jnp.asarray(rng.permutation(4096)[:256], jnp.int32)
    lens = jnp.asarray(rng.integers(64, 1518, size=(256,)), jnp.int32)
    f = jax.jit(lambda a, s, l: ops.burst_gather(a, s, l, 1518,
                                                 impl="chunked"))
    us = _time(f, arena, slots, lens)
    emit("kernel_burst_gather_256pkt", us,
         f"gb_s={256*1518/us/1e3:.2f}")


if __name__ == "__main__":
    run()
