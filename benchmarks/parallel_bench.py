"""Partitioned-parallel benchmark: sweep throughput + partitioned incast.

Two measurements, written to ``BENCH_parallel.json``:

* **sweep** — the Fig. 3(a) grid (:func:`benchmarks.suite.fig3a_grid`) run
  serially and then across a worker pool; reports trials/s for both and the
  ratio.  The merged suite artifacts are compared for equality first — a
  parallel runner that changes results is worthless, so a mismatch exits
  hard.
* **partition** — an 8-node/16-client incast (per-client targets spread
  clients over every node, 5 µs links so the conservative window has real
  lookahead) run under ``shared-clock``, ``partitioned``, and
  ``partitioned-mp``.  The partitioned reports must be **bit-identical** to
  the shared-clock report (hard exit otherwise); wall times and the speedup
  ratios ride alongside.

Speedups depend on host cores: this container is frequently 1-CPU, where a
worker pool only adds IPC overhead — the JSON records ``host_cpus`` so the
numbers read honestly, and the ≥N× speedup gates are opt-in flags
(``--assert-sweep-speedup`` / ``--assert-partition-speedup``) meant for
multi-core CI runners, not a hard-coded assertion that can only pass on big
machines.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Optional

from repro.core import PartitionRunInfo
from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)

from . import suite as suite_mod
from .common import emit


def incast_topology(n_nodes: int = 8, n_clients: int = 16,
                    rate_gbps: float = 1.0, duration_s: float = 0.0004,
                    link_latency_ns: int = 5_000) -> TopologyConfig:
    """N-node incast with per-client targets: client g hammers node g%N, so
    every node domain (not just one hot egress) carries traffic — the shape
    partitioned execution is built for."""
    nodes = tuple(
        NodeConfig(name=f"n{i}", pool=PoolConfig(n_slots=8192),
                   port=PortConfig(ring_size=1024, writeback_threshold=1),
                   stack=StackConfig(kind="bypass", burst_size=32))
        for i in range(n_nodes))
    return TopologyConfig(
        name=f"parallel-incast-{n_nodes}n{n_clients}c",
        nodes=nodes,
        n_clients=n_clients,
        client_targets=tuple(f"n{g % n_nodes}" for g in range(n_clients)),
        switch=SwitchConfig(egress_capacity=64,
                            link=LinkConfig(gbps=10.0,
                                            latency_ns=link_latency_ns)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=rate_gbps,
                              packet_size=512, duration_s=duration_s,
                              seed=7, sim_time=True))


def _sweep_section(quick: bool, workers: int) -> Dict[str, Any]:
    trials = suite_mod.fig3a_grid(trial_s=0.0008 if quick else 0.002)
    serial_merged, serial_t = suite_mod.run_suite(trials, workers=1)
    par_merged, par_t = suite_mod.run_suite(trials, workers=workers)
    if json.dumps(serial_merged, sort_keys=True) != \
            json.dumps(par_merged, sort_keys=True):
        raise SystemExit(
            "parallel sweep FAILED parity: worker-pool merged artifact "
            "differs from the serial one")
    speedup = (par_t["trials_per_s"] / serial_t["trials_per_s"]
               if serial_t["trials_per_s"] > 0 else 0.0)
    emit("parallel_sweep_serial", serial_t["wall_s"] * 1e6 / max(
        1, serial_t["n_trials"]),
         f"trials_per_s={serial_t['trials_per_s']:.3f}")
    emit("parallel_sweep_workers", par_t["wall_s"] * 1e6 / max(
        1, par_t["n_trials"]),
         f"trials_per_s={par_t['trials_per_s']:.3f};workers={workers};"
         f"speedup={speedup:.2f}")
    return {"n_trials": serial_t["n_trials"], "workers": workers,
            "serial_wall_s": serial_t["wall_s"],
            "parallel_wall_s": par_t["wall_s"],
            "serial_trials_per_s": serial_t["trials_per_s"],
            "parallel_trials_per_s": par_t["trials_per_s"],
            "speedup": speedup, "parity": "identical"}


def _partition_section(quick: bool) -> Dict[str, Any]:
    cfg = incast_topology(duration_s=0.0003 if quick else 0.001)
    walls: Dict[str, float] = {}
    reports: Dict[str, Dict[str, Any]] = {}
    infos: Dict[str, PartitionRunInfo] = {}
    for mode in ("shared-clock", "partitioned", "partitioned-mp"):
        pi = PartitionRunInfo()
        t0 = time.perf_counter()  # simlint: disable=SL001 -- bench wall timing
        rep = run_topology_experiment(cfg.with_partition(mode),
                                      partition_info=pi)
        walls[mode] = time.perf_counter() - t0  # simlint: disable=SL001 -- bench wall timing
        reports[mode] = rep.to_dict()
        infos[mode] = pi
    for mode in ("partitioned", "partitioned-mp"):
        if infos[mode].mode_used != mode:
            raise SystemExit(
                f"{mode} FAILED to engage: fell back to "
                f"{infos[mode].mode_used!r} ({infos[mode].fallback_reason})")
        if reports[mode] != reports["shared-clock"]:
            raise SystemExit(
                f"{mode} FAILED parity: report differs from shared-clock "
                "on the incast topology")
    out: Dict[str, Any] = {
        "topology": {"n_nodes": len(cfg.nodes), "n_clients": cfg.n_clients,
                     "link_latency_ns": cfg.switch.link.latency_ns,
                     "duration_s": cfg.traffic.duration_s},
        "sent": reports["shared-clock"]["sent"],
        "received": reports["shared-clock"]["received"],
        "n_domains": infos["partitioned"].n_domains,
        "n_windows": infos["partitioned"].n_windows,
        "mp_workers": infos["partitioned-mp"].n_workers,
        "parity": "identical",
    }
    for mode in walls:
        out[f"{mode}_wall_s"] = walls[mode]
    for mode in ("partitioned", "partitioned-mp"):
        ratio = walls["shared-clock"] / walls[mode] if walls[mode] > 0 else 0.0
        out[f"{mode}_speedup"] = ratio
        emit(f"parallel_{mode.replace('-', '_')}", walls[mode] * 1e6,
             f"speedup_vs_shared={ratio:.2f};windows="
             f"{infos['partitioned'].n_windows}")
    return out


def run(quick: bool = True, workers: int = 4,
        out_json: Optional[str] = "BENCH_parallel.json",
        assert_sweep_speedup: Optional[float] = None,
        assert_partition_speedup: Optional[float] = None) -> Dict[str, Any]:
    result: Dict[str, Any] = {
        "host_cpus": os.cpu_count(),
        "quick": quick,
        "sweep": _sweep_section(quick, workers),
        "partition": _partition_section(quick),
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
    if assert_sweep_speedup is not None and \
            result["sweep"]["speedup"] < assert_sweep_speedup:
        raise SystemExit(
            f"sweep speedup {result['sweep']['speedup']:.2f}x < required "
            f"{assert_sweep_speedup}x (host_cpus={result['host_cpus']})")
    if assert_partition_speedup is not None and \
            result["partition"]["partitioned-mp_speedup"] < \
            assert_partition_speedup:
        raise SystemExit(
            f"partitioned-mp speedup "
            f"{result['partition']['partitioned-mp_speedup']:.2f}x < "
            f"required {assert_partition_speedup}x "
            f"(host_cpus={result['host_cpus']})")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--out", default="BENCH_parallel.json")
    ap.add_argument("--assert-sweep-speedup", type=float, default=None,
                    help="fail unless the worker-pool sweep is >= this many "
                    "times faster (trials/s) than serial")
    ap.add_argument("--assert-partition-speedup", type=float, default=None,
                    help="fail unless partitioned-mp beats shared-clock "
                    "wall time by >= this factor")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick, workers=args.workers, out_json=args.out,
        assert_sweep_speedup=args.assert_sweep_speedup,
        assert_partition_speedup=args.assert_partition_speedup)


if __name__ == "__main__":
    main()
