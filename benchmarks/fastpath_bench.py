"""Fast-path throughput tracking: simulated packets/sec, event vs epoch vs
jit-epoch, on the fig3a-style 100 GbE single-host trial (ISSUE 6 acceptance:
epoch >= 50x event), plus the event/epoch report-parity check.

Emits the usual CSV rows and a machine-readable ``BENCH_fastpath.json`` so
speedups are tracked PR-over-PR.  Runnable standalone for CI::

    PYTHONPATH=src python -m benchmarks.fastpath_bench --quick
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

from repro.exp import TrafficConfig, run_experiment

from .common import emit, experiment_config

# the sustaining 100 GbE shape: 8 RSS queues on 8 lcores keeps per-lcore
# service (~551 ns per 1518B pkt) under the 8-way-split arrival rate, so the
# run stays in the fast-path regime (no ring fill, no drops) on both engines
BENCH_KW = dict(stack="bypass", nports=1, n_queues=8, n_lcores=8, ring=1024,
                writeback_threshold=32, burst=64, pool_slots=16384)
RATE_GBPS = 100.0
PACKET_SIZE = 1518


def _cfg(engine: str, duration_s: float):
    return experiment_config(
        name=f"fastpath-{engine}",
        traffic=TrafficConfig(mode="open_loop", rate_gbps=RATE_GBPS,
                              packet_size=PACKET_SIZE, duration_s=duration_s,
                              engine=engine),
        **BENCH_KW)


def _run(engine: str, duration_s: float) -> Dict[str, float]:
    t0 = time.perf_counter()  # simlint: disable=SL001 -- bench wall timing
    rep = run_experiment(_cfg(engine, duration_s))
    wall = time.perf_counter() - t0  # simlint: disable=SL001 -- bench wall timing
    return {"duration_s": duration_s, "packets": float(rep.sent),
            "received": float(rep.received), "wall_s": wall,
            "sim_pkts_per_s": rep.sent / wall if wall > 0 else 0.0}


def _report_key(rep):
    lat = None if rep.latency is None else rep.latency.as_dict()
    return (rep.offered_gbps, rep.achieved_gbps, rep.achieved_mpps, rep.sent,
            rep.received, rep.dropped, lat, sorted(rep.extras.items()))


def parity_check(duration_s: float = 0.004) -> bool:
    """Bit-identical RunReports, event vs epoch, on one bench config."""
    rep_e = run_experiment(_cfg("event", duration_s))
    rep_f = run_experiment(_cfg("epoch", duration_s))
    return _report_key(rep_e) == _report_key(rep_f)


def run(out_json: Optional[str] = "BENCH_fastpath.json",
        quick: bool = False) -> Dict[str, object]:
    # the event loop pays per-packet Python rounds, so it gets a shorter
    # virtual window; pkts/s normalizes wall cost per simulated packet
    event_s = 0.004 if quick else 0.02
    epoch_s = 0.02 if quick else 0.1
    results = {"event": _run("event", event_s),
               "epoch": _run("epoch", epoch_s),
               "epoch-jit": _run("epoch-jit", epoch_s)}
    base = results["event"]["sim_pkts_per_s"]
    speedups = {eng: (r["sim_pkts_per_s"] / base if base > 0 else 0.0)
                for eng, r in results.items()}
    parity = parity_check()
    for eng, r in results.items():
        emit(f"fastpath_{eng}", r["wall_s"] / r["packets"] * 1e6 if
             r["packets"] else 0.0,
             f"sim_pkts_per_s={r['sim_pkts_per_s']:.0f};"
             f"speedup={speedups[eng]:.1f}x")
    emit("fastpath_parity", 0.0, f"bit_identical={parity}")
    payload = {
        "bench": "fastpath",
        "config": {**BENCH_KW, "rate_gbps": RATE_GBPS,
                   "packet_size": PACKET_SIZE},
        "engines": results,
        "speedup_vs_event": speedups,
        "parity_bit_identical": parity,
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_fastpath.json")
    ap.add_argument("--quick", action="store_true",
                    help="short windows (CI smoke)")
    ap.add_argument("--assert-speedup", type=float, default=None,
                    help="fail unless epoch >= this many x over event")
    args = ap.parse_args()
    payload = run(out_json=args.out, quick=args.quick)
    if not payload["parity_bit_identical"]:
        raise SystemExit("event/epoch RunReport parity check FAILED")
    if (args.assert_speedup is not None
            and payload["speedup_vs_event"]["epoch"] < args.assert_speedup):
        raise SystemExit(
            f"epoch speedup {payload['speedup_vs_event']['epoch']:.1f}x "
            f"< required {args.assert_speedup}x")


if __name__ == "__main__":
    main()
