"""Benchmark driver: one section per paper table, plus suite sweeps.

CSV sections emit ``name,us_per_call,derived`` rows through
:data:`benchmarks.common.ROWS`; "text" sections (fastpath, parallel) deliver
primarily through their JSON artifacts.  The CSV header appears only when a
selected section is a CSV one — ``--only fastpath`` no longer prints a
stray header over a JSON-artifact run.  ``--out`` writes the structured
per-section report (every emitted row, grouped by section) as JSON.

``--suite <grid> --workers N`` bypasses the sections entirely and runs a
declarative sweep grid (:mod:`benchmarks.suite`) across a worker pool,
writing the merged trial artifact to ``--out``.
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence, Tuple

Section = Tuple[str, str, object]  # (name, "csv" | "text", thunk)


def select_sections(sections: Sequence[Section],
                    only: Optional[str]) -> List[Section]:
    """The sections one invocation will run (all of them, or the ``--only``
    pick)."""
    if only is None:
        return list(sections)
    return [s for s in sections if s[0] == only]


def needs_csv_header(sections: Sequence[Section]) -> bool:
    """True iff any selected section emits CSV rows — the only case the
    ``name,us_per_call,derived`` header belongs in the output."""
    return any(fmt == "csv" for _name, fmt, _fn in sections)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["fig3a", "fig3b", "fig4", "incast", "aqm",
                             "serving", "latency", "kernels", "roofline",
                             "fastpath", "parallel"])
    # VIRTUAL seconds per MSB trial since the SimClock refactor: a few ms of
    # simulated traffic is statistically plenty and runs fast at any rate
    ap.add_argument("--trial-s", type=float, default=0.004)
    ap.add_argument("--out", default=None,
                    help="write the structured section report (or the suite "
                    "artifact with --suite) to this JSON path")
    ap.add_argument("--workers", type=int, default=4,
                    help="worker-pool size for --suite and the parallel "
                    "section")
    ap.add_argument("--suite", default=None,
                    help="run a named sweep grid (e.g. fig3a-grid) through "
                    "the parallel suite runner instead of the sections")
    ap.add_argument("--cache-dir", default=None,
                    help="per-trial result cache for --suite (content-keyed; "
                    "re-runs only changed configs)")
    args = ap.parse_args()

    if args.suite:
        from . import suite as suite_mod
        trials = suite_mod.named_grid(args.suite, trial_s=args.trial_s)
        merged, timing = suite_mod.run_suite(trials, workers=args.workers,
                                             cache_dir=args.cache_dir)
        out = args.out or f"SUITE_{args.suite}.json"
        suite_mod.write_suite_json(out, merged)
        print(f"# suite {args.suite}: {timing['n_trials']} trials "
              f"({timing['n_cache_hits']} cached) in {timing['wall_s']:.2f}s "
              f"= {timing['trials_per_s']:.2f} trials/s "
              f"[workers={timing['workers']}] -> {out}")
        return

    from . import (fastpath_bench, fig3a_scalability, fig3b_sensitivity,
                   fig4_dca_burst, fig_aqm, fig_incast, fig_serving,
                   kernels_bench, parallel_bench, roofline, tbl_latency)
    from .common import ROWS

    sections: List[Section] = [
        ("fig3a", "csv", lambda: fig3a_scalability.run(trial_s=args.trial_s)),
        ("fig3b", "csv", lambda: fig3b_sensitivity.run(trial_s=args.trial_s)),
        ("fig4", "csv", lambda: fig4_dca_burst.run(duration_s=args.trial_s)),
        ("incast", "csv",
         lambda: fig_incast.run(trial_s=min(args.trial_s, 0.001))),
        ("aqm", "csv", lambda: fig_aqm.run(trial_s=min(args.trial_s, 0.005))),
        ("serving", "csv",
         lambda: fig_serving.run(trial_s=min(args.trial_s, 0.002))),
        ("latency", "csv", tbl_latency.run),
        ("kernels", "csv", kernels_bench.run),
        ("roofline", "csv", roofline.run),
        ("fastpath", "text", lambda: fastpath_bench.run(quick=True)),
        ("parallel", "text",
         lambda: parallel_bench.run(quick=True, workers=args.workers)),
    ]
    selected = select_sections(sections, args.only)
    if needs_csv_header(selected):
        print("name,us_per_call,derived")
    report = {}
    for name, _fmt, fn in selected:
        before = len(ROWS)
        fn()
        report[name] = ROWS[before:]
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"sections": report}, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == '__main__':
    main()
