# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["fig3a", "fig3b", "fig4", "incast", "serving",
                             "latency", "kernels", "roofline", "fastpath"])
    # VIRTUAL seconds per MSB trial since the SimClock refactor: a few ms of
    # simulated traffic is statistically plenty and runs fast at any rate
    ap.add_argument("--trial-s", type=float, default=0.004)
    args = ap.parse_args()

    from . import (fastpath_bench, fig3a_scalability, fig3b_sensitivity,
                   fig4_dca_burst, fig_incast, fig_serving, kernels_bench,
                   roofline, tbl_latency)

    sections = [
        ("fig3a", lambda: fig3a_scalability.run(trial_s=args.trial_s)),
        ("fig3b", lambda: fig3b_sensitivity.run(trial_s=args.trial_s)),
        ("fig4", lambda: fig4_dca_burst.run(duration_s=args.trial_s)),
        ("incast", lambda: fig_incast.run(trial_s=min(args.trial_s, 0.001))),
        ("serving", lambda: fig_serving.run(trial_s=min(args.trial_s, 0.002))),
        ("latency", tbl_latency.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline.run),
        ("fastpath", lambda: fastpath_bench.run(quick=True)),
    ]
    print("name,us_per_call,derived")
    for name, fn in sections:
        if args.only and name != args.only:
            continue
        fn()


if __name__ == '__main__':
    main()
