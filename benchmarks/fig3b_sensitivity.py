"""Paper Fig. 3(b): sensitivity of both stacks to host parameters.

The paper applies cumulative µarch upgrades (2→3 GHz, low-latency PCIe, 2×
mem channels, 2× ROB/LSQ, ..., DCA) and shows the kernel stack responds
strongly (+32.5% from frequency alone) while DPDK barely moves (+1.2%).

Host-parameter mapping (DESIGN.md §2 — the modeled costs are exactly the
gem5-timed kernel events; real code is not modeled):

  3GHz CPU        → CostConfig(cpu_ghz=3.0): all syscall/IRQ cycles shrink
  low-lat PCIe    → interrupt_cycles halved (IRQ delivery path)
  2x sockbuf      → read() drains 32 packets per syscall (socket buffer/LSQ)
  2x ring         → descriptor rings doubled (more buffering)
  2x burst        → PMD burst 64→128 (DPDK-side knob; kernel stack unaffected)

Each upgrade is cumulative on top of the previous, like the paper.  Every
step is a declarative config delta (`dataclasses.replace` on frozen
:class:`repro.exp.CostConfig`), not a hand-built testbed.
"""
from __future__ import annotations

from dataclasses import replace

from repro.exp import CostConfig

from .common import emit, msb


def run(trial_s: float = 0.004) -> dict:
    base_cost = CostConfig(cpu_ghz=2.0)
    steps = [
        ("base_2ghz", dict(cost=base_cost, ring=1024, burst=64,
                           sockbuf_budget=16)),
        ("3ghz_cpu", dict(cost=replace(base_cost, cpu_ghz=3.0), ring=1024,
                          burst=64, sockbuf_budget=16)),
        ("low_lat_pcie", dict(cost=replace(base_cost, cpu_ghz=3.0,
                                           interrupt_cycles=4000),
                              ring=1024, burst=64, sockbuf_budget=16)),
        ("2x_sockbuf", dict(cost=replace(base_cost, cpu_ghz=3.0,
                                         interrupt_cycles=4000),
                            ring=1024, burst=64, sockbuf_budget=32)),
        ("2x_ring", dict(cost=replace(base_cost, cpu_ghz=3.0,
                                      interrupt_cycles=4000),
                         ring=2048, burst=64, sockbuf_budget=32)),
        ("2x_burst", dict(cost=replace(base_cost, cpu_ghz=3.0,
                                       interrupt_cycles=4000),
                          ring=2048, burst=128, sockbuf_budget=32)),
    ]
    out = {}
    base = {}
    for name, kw in steps:
        cost = kw.pop("cost")
        sockbuf = kw.pop("sockbuf_budget")
        b_gbps, b_us = msb("bypass", trial_s=trial_s, **kw)
        k_gbps, k_us = msb("kernel", trial_s=trial_s, cost=cost,
                           sockbuf_budget=sockbuf, **kw)
        if name == "base_2ghz":
            base = {"bypass": b_gbps, "kernel": k_gbps}
        d_b = 100.0 * (b_gbps / base["bypass"] - 1) if base else 0.0
        d_k = 100.0 * (k_gbps / base["kernel"] - 1) if base else 0.0
        out[name] = (b_gbps, k_gbps, d_b, d_k)
        emit(f"fig3b_bypass_{name}", b_us,
             f"msb_gbps={b_gbps:.3f};delta_vs_base_pct={d_b:+.1f}")
        emit(f"fig3b_kernel_{name}", k_us,
             f"msb_gbps={k_gbps:.3f};delta_vs_base_pct={d_k:+.1f}")
    return out


if __name__ == "__main__":
    run()
